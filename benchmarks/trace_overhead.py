"""Tracing overhead gate: disabled tracing must be ~free.

Measures what :mod:`repro.obs` adds to the served query path in the two
states a production process actually runs in: tracing **disabled**
(``trace_sample_rate = 0.0`` — the default; the per-request cost is one
attribute read and a float compare behind the guard ``tr is not None
and tr.active``) and **sampled** (rate 0.05 — one request in twenty
pays span bookkeeping, the ``block_until_ready`` launch fence, and the
cardinality-drift annotation).

Measurement design: a single cold subprocess builds the store once,
prepares the suite once (plan cache + XLA compile caches hot, drift
cache pre-filled by a rate-1.0 warmup pass), then times the same query
loop under three in-process arms — ``base`` (``engine.tracer = None``:
no obs code reachable at all), ``off`` (tracer present, rate 0.0) and
``sampled`` (rate 0.05).  The tracer re-reads the sampling rate from
``RuntimeConfig`` on every ``begin``, so the arms only mutate
``cfg.trace_sample_rate`` — prepared programs, caches and device state
are shared, and the ratio isolates the obs layer.  Each arm keeps the
min over several interleaved passes (robust to scheduler noise); the
parent takes the median ratio over cold reps.

Emits ``BENCH_trace_overhead.json``::

    {"scale": ..., "n_queries": ..., "reps": ...,
     "base_ms_per_query": ..., "off_overhead_pct": ...,
     "sampled_overhead_pct": ..., "gate_off_pct": 1.0,
     "gate_sampled_pct": 5.0, "ok": true}

and fails the harness row (derived ``FAIL``) when either overhead
exceeds its gate: off ≤ 1%, sampled ≤ 5%.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEFAULT_OUT = "BENCH_trace_overhead.json"
GATE_OFF_PCT = 1.0
GATE_SAMPLED_PCT = 5.0
SAMPLE_RATE = 0.05
REPS = 3
PASSES = 7
#: overhead is a per-query ratio, insensitive to graph scale; cap the
#: child's generation cost so the gate stays cheap to run
MAX_SCALE = 0.5


def _child(scale: float) -> None:
    """One cold process: build the store, warm every cache at rate 1.0,
    then time the serve loop under the three arms.  Prints the per-arm
    min-of-passes times as the last stdout line."""
    from repro.core.stats import build_catalog
    from repro.engine import RuntimeConfig
    from repro.engine.dataset import Dataset
    from repro.rdf.generator import WatDivConfig, generate_watdiv
    from repro.rdf.workloads import basic_queries

    tt, d, sch = generate_watdiv(WatDivConfig(scale_factor=scale, seed=7))
    cat = build_catalog(tt, d)
    ds = Dataset(cat, d, sch)
    queries = [q for insts in basic_queries(sch, n_instances=1).values()
               for q in insts]
    cfg = RuntimeConfig(trace_sample_rate=1.0)
    eng = ds.engine("jit", runtime=cfg)
    tracer = eng.tracer
    # warmup at rate 1.0: compiles every program, fills the plan cache
    # and the cardinality-drift cache, so the timed arms differ only in
    # per-request obs work
    for q in queries:
        eng.query(q)

    def arm(rate, with_tracer):
        cfg.trace_sample_rate = rate
        eng.tracer = tracer if with_tracer else None
        t0 = time.perf_counter()
        for q in queries:
            eng.query(q)
        return time.perf_counter() - t0

    arms = {"base": (0.0, False), "off": (0.0, True),
            "sampled": (SAMPLE_RATE, True)}
    best = {name: float("inf") for name in arms}
    # interleave the arms within each pass so drift (thermal, page
    # cache) hits all three equally; min-of-passes drops outliers
    for _ in range(PASSES):
        for name, (rate, with_tracer) in arms.items():
            best[name] = min(best[name], arm(rate, with_tracer))
    print(json.dumps({"base_s": best["base"], "off_s": best["off"],
                      "sampled_s": best["sampled"],
                      "n_queries": len(queries)}))


def _spawn(scale: float) -> dict:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child",
         "--scale", str(scale)],
        env=env, cwd=root, capture_output=True, text=True, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(scale: float = 5.0, csv=None, out_path: str = DEFAULT_OUT) -> dict:
    scale = min(scale, MAX_SCALE)
    results = [_spawn(scale) for _ in range(REPS)]
    off = sorted(r["off_s"] / r["base_s"] for r in results)
    sam = sorted(r["sampled_s"] / r["base_s"] for r in results)
    base = sorted(r["base_s"] for r in results)
    n = results[0]["n_queries"]
    off_pct = (off[len(off) // 2] - 1.0) * 100.0
    sam_pct = (sam[len(sam) // 2] - 1.0) * 100.0
    report = {
        "scale": scale, "n_queries": n, "reps": REPS, "passes": PASSES,
        "base_ms_per_query": base[len(base) // 2] / n * 1e3,
        "off_overhead_pct": off_pct, "sampled_overhead_pct": sam_pct,
        "sample_rate": SAMPLE_RATE,
        "gate_off_pct": GATE_OFF_PCT, "gate_sampled_pct": GATE_SAMPLED_PCT,
        "ok": off_pct < GATE_OFF_PCT and sam_pct < GATE_SAMPLED_PCT,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    if csv is not None:
        csv.add("trace_overhead", base[len(base) // 2] / n * 1e6,
                f"off={off_pct:.2f}% sampled={sam_pct:.2f}%"
                + ("" if report["ok"] else " FAIL"))
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=5.0)
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.child:
        _child(min(args.scale, MAX_SCALE))
        return
    report = run(scale=args.scale, out_path=args.out)
    print(json.dumps(report, indent=2))
    if not report["ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
