"""Shared benchmark utilities: dataset/caches, timing, CSV emission."""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.compiler import compile_bgp
from repro.core.executor import execute
from repro.core.sparql import parse_sparql
from repro.core.stats import Catalog, build_catalog
from repro.rdf.generator import WatDivConfig, WatDivSchema, generate_watdiv

_DATASETS: Dict[Tuple[float, int], Tuple[np.ndarray, object, WatDivSchema]] = {}
_CATALOGS: Dict[Tuple[float, int, float, bool], Catalog] = {}


def dataset(scale: float, seed: int = 0):
    key = (scale, seed)
    if key not in _DATASETS:
        _DATASETS[key] = generate_watdiv(WatDivConfig(scale_factor=scale,
                                                      seed=seed))
    return _DATASETS[key]


def catalog(scale: float, seed: int = 0, threshold: float = 1.0,
            with_extvp: bool = True) -> Catalog:
    key = (scale, seed, threshold, with_extvp)
    if key not in _CATALOGS:
        tt, d, sch = dataset(scale, seed)
        _CATALOGS[key] = build_catalog(tt, d, threshold=threshold,
                                       with_extvp=with_extvp)
    return _CATALOGS[key]


def time_query(qtext: str, cat: Catalog, layout: str,
               repeats: int = 3) -> Tuple[float, int]:
    """(best-of-N seconds, result rows)."""
    d = cat.dictionary
    q = parse_sparql(qtext, d)
    best = float("inf")
    rows = 0
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = execute(q, cat, layout=layout)
        dt = time.perf_counter() - t0
        best = min(best, dt)
        rows = len(res)
    return best, rows


class Csv:
    """Collects `name,us_per_call,derived` rows (the harness contract)."""

    def __init__(self) -> None:
        self.rows: List[Tuple[str, float, str]] = []

    def add(self, name: str, seconds: float, derived: str = "") -> None:
        self.rows.append((name, seconds * 1e6, derived))

    def emit(self) -> None:
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}")
