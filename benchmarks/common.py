"""Shared benchmark utilities: dataset/catalog caches, timing, CSV emission.

All query execution routes through the unified ``Dataset``/``Engine``
facade (``repro.engine``); the per-table benchmark modules keep consuming
the same ``dataset()`` / ``catalog()`` / ``time_query()`` helpers.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.core.stats import Catalog, build_catalog
from repro.engine import Dataset, Engine
from repro.rdf.generator import WatDivConfig, generate_watdiv

_RAW: Dict[Tuple[float, int], tuple] = {}
_DATASETS: Dict[Tuple[float, int, float, bool], Dataset] = {}
_ENGINES: Dict[Tuple[int, str], Engine] = {}


def _raw(scale: float, seed: int = 0):
    key = (scale, seed)
    if key not in _RAW:
        _RAW[key] = generate_watdiv(WatDivConfig(scale_factor=scale,
                                                 seed=seed))
    return _RAW[key]


def facade(scale: float, seed: int = 0, threshold: float = 1.0,
           with_extvp: bool = True) -> Dataset:
    """The cached ``Dataset`` for a WatDiv configuration (the generated
    graph is shared across thresholds; only the store is rebuilt)."""
    key = (scale, seed, threshold, with_extvp)
    if key not in _DATASETS:
        tt, d, sch = _raw(scale, seed)
        cat = build_catalog(tt, d, threshold=threshold,
                            with_extvp=with_extvp)
        _DATASETS[key] = Dataset(catalog=cat, dictionary=d, schema=sch)
    return _DATASETS[key]


def dataset(scale: float, seed: int = 0):
    """(tt, dictionary, schema) triple — the raw-store view."""
    return _raw(scale, seed)


def catalog(scale: float, seed: int = 0, threshold: float = 1.0,
            with_extvp: bool = True) -> Catalog:
    return facade(scale, seed, threshold, with_extvp).catalog


DEFAULT_BACKEND = "eager"


def set_default_backend(name: str) -> None:
    """Route every ``time_query`` through a different ExecutionBackend
    (``benchmarks/run.py --backend jit``)."""
    global DEFAULT_BACKEND
    DEFAULT_BACKEND = name


def engine_for(cat: Catalog, layout: str, backend: str = None) -> Engine:
    """An Engine over an already-built catalog (cached per catalog+layout,
    so templated benchmark queries hit the plan cache across repeats)."""
    backend = backend or DEFAULT_BACKEND
    key = (id(cat), f"{backend}/{layout}")
    if key not in _ENGINES:
        ds = Dataset(catalog=cat, dictionary=cat.dictionary)
        _ENGINES[key] = ds.engine(backend, layout=layout)
    return _ENGINES[key]


def time_query(qtext: str, cat: Catalog, layout: str,
               repeats: int = 3) -> Tuple[float, int]:
    """(best-of-N seconds, result rows)."""
    eng = engine_for(cat, layout)
    best = float("inf")
    rows = 0
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = eng.query(qtext)
        dt = time.perf_counter() - t0
        best = min(best, dt)
        rows = len(res)
    return best, rows


class Csv:
    """Collects `name,us_per_call,derived` rows (the harness contract)."""

    def __init__(self) -> None:
        self.rows: List[Tuple[str, float, str]] = []

    def add(self, name: str, seconds: float, derived: str = "") -> None:
        self.rows.append((name, seconds * 1e6, derived))

    def emit(self) -> None:
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}")
