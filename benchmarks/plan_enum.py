"""Planner A/B gate: greedy (Algorithm 4) vs cardinality-estimate plan
enumeration over the WatDiv basic suite (star/linear/snowflake/complex).

Two engines share one dataset — identical tables, plan caches keyed on
the planner knob — and every template instance is timed in **paired,
calibrated blocks**: each repetition times a >=``BLOCK_SECONDS`` loop of
the query under one planner, then immediately under the other (order
alternating), and contributes one greedy/estimate latency *ratio*.
Pairing adjacent-in-time blocks cancels slow clock/load drift that
independent best-of-N timing cannot; the per-template speedup is the
median of the paired ratios.

``speedup`` is a **plan-level** quantity: when both planners chose the
byte-identical join order on every instance of a template the two
engines execute the same plan, so the speedup is identically 1.0 by
construction and is reported as such (the raw measured times are still
recorded); any measured delta there is harness noise, not planner
behavior.  Wins and regressions can therefore only come from genuinely
different join orders — exactly what the gate is about.

The CI gate (``tests-pallas``) fails if:
* the estimate planner is < ``MIN_SPEEDUP``x greedy on ANY template
  (estimation must never wreck a query), or
* it is not strictly faster on at least one snowflake (F*) or complex
  (C*) template (the statistics must buy something where join trees are
  deep enough to matter).

Emits ``BENCH_plan_enum.json``::

    {"scale": ..., "n_queries": ...,
     "templates": {name: {"greedy_s": ..., "estimate_s": ...,
                          "speedup": ..., "order_differs": ...}},
     "gate": {"min_speedup": ..., "fc_wins": [...]}}
"""

from __future__ import annotations

import argparse
import json
import time
from statistics import median
from typing import Dict, List, Optional

from benchmarks.common import Csv, facade
from repro.engine import RuntimeConfig
from repro.rdf.workloads import basic_queries

DEFAULT_OUT = "BENCH_plan_enum.json"
MIN_SPEEDUP = 0.95     # estimate must stay within 5% of greedy everywhere
GATE_SCALE = 1.0       # the scale the gate thresholds are calibrated at
                       # (CI runs --scale 1.0); other scales still emit
                       # the full report but only warn — the uniform join
                       # model's known C2 fan-out underestimate grows
                       # with scale (docs/architecture.md)
REPEATS = 5            # paired ratio samples per instance (same-order)
REPEATS_DIFF = 33      # ...and where the orders genuinely differ: only
                       # these templates can trip the gate, so buy the
                       # sampling depth to make their medians stable
BLOCK_SECONDS = 0.01   # calibrated timed-block floor: a 5% delta on a
                       # >=10ms block is resolvable; single sub-ms query
                       # executions are not


def _order_key(prepared):
    plan = getattr(prepared, "plan", None)
    if plan is None or getattr(plan, "empty", False):
        return ()
    return tuple(str(s.tp) for s in plan.steps)


def _timed_block(eng, qtext: str, iters: int) -> float:
    t0 = time.perf_counter()
    for _ in range(iters):
        eng.query(qtext)
    return (time.perf_counter() - t0) / iters


def run(scale: float = 1.0, csv: Optional[Csv] = None,
        out_path: str = DEFAULT_OUT) -> Dict[str, object]:
    ds = facade(scale)
    queries = basic_queries(ds.schema, seed=42, n_instances=3)
    engines = {
        "greedy": ds.engine("eager", runtime=RuntimeConfig(planner="greedy")),
        "estimate": ds.engine("eager",
                              runtime=RuntimeConfig(planner="estimate")),
    }

    # warm both plan caches (and the template cache) so compile time and
    # first-touch table faults never land inside a timed repetition
    for instances in queries.values():
        for qtext in instances:
            for eng in engines.values():
                eng.query(qtext)

    templates: Dict[str, Dict[str, object]] = {}
    for name, instances in queries.items():
        order_differs = any(
            _order_key(engines["greedy"].prepare(qtext)) !=
            _order_key(engines["estimate"].prepare(qtext))
            for qtext in instances)
        repeats = REPEATS_DIFF if order_differs else REPEATS
        ratios: List[float] = []
        times = {"greedy": [], "estimate": []}
        for qtext in instances:
            # calibrate a shared iteration count so every timed block
            # runs >= BLOCK_SECONDS; both planners use the SAME count
            once = max(_timed_block(engines["greedy"], qtext, 1), 1e-7)
            iters = max(1, int(BLOCK_SECONDS / once) + 1)
            b = {"greedy": float("inf"), "estimate": float("inf")}
            for rep in range(repeats):
                order = list(engines.items())
                if rep % 2:
                    order.reverse()
                pair = {}
                for planner, eng in order:
                    pair[planner] = _timed_block(eng, qtext, iters)
                ratios.append(pair["greedy"] / max(pair["estimate"], 1e-12))
                for planner in engines:
                    b[planner] = min(b[planner], pair[planner])
            for planner in engines:
                times[planner].append(b[planner])
        g = sum(times["greedy"]) / len(times["greedy"])
        e = sum(times["estimate"]) / len(times["estimate"])
        # identical join orders => identical plans => speedup is 1.0 by
        # construction; otherwise the median paired ratio
        speedup = median(ratios) if order_differs else 1.0
        templates[name] = {"greedy_s": g, "estimate_s": e,
                           "speedup": speedup,
                           "order_differs": order_differs}
        if csv is not None:
            csv.add(f"plan_enum/{name}", e,
                    f"speedup={speedup:.2f}x "
                    f"order_diff={int(order_differs)}")

    # --- the gate (report is written FIRST so a failing gate still
    # leaves the artifact for the CI upload) ---------------------------
    worst = min(t["speedup"] for t in templates.values())
    fc_wins = sorted(n for n, t in templates.items()
                     if n[0] in "FC" and t["speedup"] > 1.0)
    n_queries = sum(len(v) for v in queries.values())
    report = {"scale": scale, "n_queries": n_queries,
              "repeats": REPEATS, "templates": templates,
              "gate": {"min_speedup": worst, "fc_wins": fc_wins}}
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    if csv is not None:
        csv.add("plan_enum/gate", 0.0,
                f"min_speedup={worst:.2f}x fc_wins={len(fc_wins)}")

    if scale != GATE_SCALE:
        if worst < MIN_SPEEDUP or not fc_wins:
            print(f"plan_enum: gate thresholds are calibrated at scale "
                  f"{GATE_SCALE} (got {scale}); min_speedup={worst:.3f}x "
                  f"fc_wins={fc_wins} reported without enforcement")
        return report
    for name, t in sorted(templates.items()):
        assert t["speedup"] >= MIN_SPEEDUP, (
            f"plan_enum gate: estimate planner is {t['speedup']:.3f}x "
            f"greedy on {name} (< {MIN_SPEEDUP}x) — the estimator chose "
            f"a worse join order than Algorithm 4")
    assert fc_wins, (
        "plan_enum gate: estimate planner beat greedy on NO snowflake/"
        "complex template — the statistics bought nothing where join "
        "trees are deep")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    csv = Csv()
    run(scale=args.scale, csv=csv, out_path=args.out)
    csv.emit()
