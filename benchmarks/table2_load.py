"""Paper Table 2: load times and store sizes (VP vs ExtVP vs τ-thresholded
ExtVP), plus the table-count accounting (#empty, #identity, #stored)."""

from __future__ import annotations

from benchmarks.common import Csv, catalog, dataset


def run(scale: float = 1.0, csv: Csv | None = None) -> Csv:
    csv = csv or Csv()
    tt, d, sch = dataset(scale)
    cat = catalog(scale)                     # τ = 1.0 (full ExtVP)
    rep = cat.storage_report()
    n = rep["n_triples"]

    csv.add("table2/triples", 0.0, f"{int(n)}")
    csv.add("table2/vp_build", rep["vp_build_seconds"],
            f"tables={int(rep['vp_tables'])};tuples={int(rep['vp_tuples'])}")
    csv.add("table2/extvp_build", rep["extvp_build_seconds"],
            f"tables={int(rep['extvp_tables'])};tuples={int(rep['extvp_tuples'])}"
            f";xVP={rep['extvp_over_vp']:.2f}"
            f";empty={int(rep['extvp_empty'])};identity={int(rep['extvp_identity'])}"
            f";semijoins={int(rep['n_semijoins'])}")

    for tau in (0.25, 0.5):
        cat_t = catalog(scale, threshold=tau)
        rep_t = cat_t.storage_report()
        csv.add(f"table2/extvp_tau{tau}", rep_t["extvp_build_seconds"],
                f"tables={int(rep_t['extvp_tables'])}"
                f";tuples={int(rep_t['extvp_tuples'])}"
                f";xVP={rep_t['extvp_over_vp']:.2f}")
    return csv


if __name__ == "__main__":
    run().emit()
