"""Paper Table 2: load times and store sizes (VP vs ExtVP vs τ-thresholded
ExtVP), plus the table-count accounting (#empty, #identity, #stored) and
the ExtVP build-backend microbenchmark.

``bench_extvp`` compares the sequential numpy builder against the
pair-batched device pipeline (``build_extvp(backend="jax")``) on
synthetic graphs of growing predicate count P (the pair grid is P²·3, so
P is the scalability axis) and on the WatDiv smoke graph, verifying
byte-identical output and emitting ``BENCH_extvp_build.json``::

    {"pair_batch": ..., "cases": [
        {"name": "P32", "preds": 32, "semijoins": ..., "numpy_s": ...,
         "jax_s": ..., "speedup": ..., "identical": true}, ...]}
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from benchmarks.common import Csv, catalog, dataset
from repro.core.vp import build_extvp, build_vp

DEFAULT_OUT = "BENCH_extvp_build.json"


def run(scale: float = 1.0, csv: Csv | None = None,
        pred_counts: Sequence[int] = (8, 32, 64)) -> Csv:
    csv = csv or Csv()
    tt, d, sch = dataset(scale)
    cat = catalog(scale)                     # τ = 1.0 (full ExtVP)
    rep = cat.storage_report()
    n = rep["n_triples"]

    csv.add("table2/triples", 0.0, f"{int(n)}")
    csv.add("table2/vp_build", rep["vp_build_seconds"],
            f"tables={int(rep['vp_tables'])};tuples={int(rep['vp_tuples'])}")
    csv.add("table2/extvp_build", rep["extvp_build_seconds"],
            f"tables={int(rep['extvp_tables'])};tuples={int(rep['extvp_tuples'])}"
            f";xVP={rep['extvp_over_vp']:.2f}"
            f";empty={int(rep['extvp_empty'])};identity={int(rep['extvp_identity'])}"
            f";semijoins={int(rep['n_semijoins'])}")

    for tau in (0.25, 0.5):
        cat_t = catalog(scale, threshold=tau)
        rep_t = cat_t.storage_report()
        csv.add(f"table2/extvp_tau{tau}", rep_t["extvp_build_seconds"],
                f"tables={int(rep_t['extvp_tables'])}"
                f";tuples={int(rep_t['extvp_tuples'])}"
                f";xVP={rep_t['extvp_over_vp']:.2f}")

    for case in bench_extvp(pred_counts=tuple(pred_counts))["cases"]:
        csv.add(f"table2/extvp_build_{case['name']}_jax", case["jax_s"],
                f"x{case['speedup']:.1f} vs numpy"
                f";semijoins={case['semijoins']}"
                f";identical={case['identical']}")
    return csv


# ---------------------------------------------------------------------------
# Build-backend microbenchmark (BENCH_extvp_build.json)
# ---------------------------------------------------------------------------

def _synthetic_graph(n_preds: int, rows_per_pred: int = 2048,
                     seed: int = 0) -> np.ndarray:
    """Random TT with ``n_preds`` predicates over a shared entity pool —
    dense enough that most pair ranges overlap (no pruning freebies)."""
    rng = np.random.default_rng(seed)
    n_ent = max(64, n_preds * rows_per_pred // 8)
    n = n_preds * rows_per_pred
    tt = np.stack([
        rng.integers(0, n_ent, n),
        n_ent + rng.integers(0, n_preds, n),
        rng.integers(0, n_ent, n),
    ], axis=1).astype(np.int32)
    return np.unique(tt, axis=0)


def _builds_identical(a, b) -> bool:
    return (a.sf == b.sf and a.sizes == b.sizes
            and set(a.tables) == set(b.tables)
            and all(np.array_equal(a.tables[k].rows, b.tables[k].rows)
                    for k in a.tables)
            and a.n_semijoins == b.n_semijoins)


def bench_extvp(pred_counts: Sequence[int] = (8, 32, 64),
                watdiv_scale: Optional[float] = 0.1,
                threshold: float = 0.25, repeats: int = 3,
                pair_batch: int = 1024,
                out_path: str = DEFAULT_OUT) -> Dict:
    """Time numpy vs pair-batched jax ExtVP builds on the same VP
    catalogs.  Compile time is excluded by one warmup build per case
    (one static batch shape per case, so the warmup covers every trace);
    an untimed numpy build first primes the ``Table`` sort/unique caches
    both paths share.  Throughput is semi-joins per second."""
    cases: List[Dict] = []
    vps = [(f"P{p}", build_vp(_synthetic_graph(p))) for p in pred_counts]
    if watdiv_scale is not None:
        tt, d, sch = dataset(watdiv_scale)
        vps.append((f"watdiv{watdiv_scale}", build_vp(tt)))

    for name, vp in vps:
        build_extvp(vp, threshold=threshold)                  # prime caches
        numpy_s = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            base = build_extvp(vp, threshold=threshold)
            numpy_s = min(numpy_s, time.perf_counter() - t0)
        build_extvp(vp, threshold=threshold, backend="jax",   # compile warmup
                    pair_batch=pair_batch)
        jax_s = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            dev = build_extvp(vp, threshold=threshold, backend="jax",
                              pair_batch=pair_batch)
            jax_s = min(jax_s, time.perf_counter() - t0)
        cases.append({
            "name": name,
            "preds": len(vp),
            "threshold": threshold,
            "semijoins": base.n_semijoins,
            "tables": len(base.tables),
            "numpy_s": numpy_s,
            "jax_s": jax_s,
            "numpy_semijoins_per_s": base.n_semijoins / max(numpy_s, 1e-9),
            "jax_semijoins_per_s": base.n_semijoins / max(jax_s, 1e-9),
            "speedup": numpy_s / max(jax_s, 1e-9),
            "identical": _builds_identical(base, dev),
        })

    report = {"pair_batch": pair_batch, "repeats": repeats, "cases": cases}
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench-only", action="store_true",
                    help="emit BENCH_extvp_build.json and skip Table 2")
    ap.add_argument("--preds", type=int, nargs="+", default=[8, 32, 64],
                    help="synthetic predicate counts for the build bench")
    ap.add_argument("--scale", type=float, default=None,
                    help="WatDiv scale: Table-2 store (default 1.0) and "
                         "the bench's WatDiv smoke case (default 0.1)")
    args = ap.parse_args()
    if args.bench_only:
        print(json.dumps(
            bench_extvp(pred_counts=tuple(args.preds),
                        watdiv_scale=args.scale if args.scale is not None
                        else 0.1),
            indent=2))
    else:
        run(scale=args.scale if args.scale is not None else 1.0,
            pred_counts=tuple(args.preds)).emit()
