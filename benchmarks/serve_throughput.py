"""Serving throughput vs micro-batch size (the batched-execution payoff).

Serves one templated workload (the ST-1-style ``follows → email`` star,
constants cycling over users) through the jit backend at micro-batch
sizes 1 / 8 / 32 and reports queries/sec.  Batch size 1 is the
per-request path (``Engine.query``); larger sizes stack the constants
into one XLA launch (``Engine.query_batch``), so the speedup measures
pure launch/dispatch amortization — compile time is excluded by a warmup
pass per batch shape.

Emits ``BENCH_serve_throughput.json``::

    {"scale": ..., "backend": "jit", "n_requests": ...,
     "qps": {"1": ..., "8": ..., "32": ...},
     "speedup_32_over_1": ...}
"""

from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

from benchmarks import common
from repro.engine import Engine

BATCH_SIZES = (1, 8, 32)
DEFAULT_OUT = "BENCH_serve_throughput.json"


def _requests(ds, n: int) -> List[str]:
    n_users = ds.schema.n_users if ds.schema is not None else 64
    return [
        f"SELECT * WHERE {{ wsdbm:User{u % n_users} wsdbm:follows ?v . "
        f"?v sorg:email ?e }}"
        for u in range(n)
    ]


def _qps(eng: Engine, requests: List[str], batch: int,
         repeats: int = 3) -> float:
    def serve_pass() -> None:
        if batch == 1:
            for q in requests:
                eng.query(q)
        else:
            for i in range(0, len(requests), batch):
                eng.query_batch(requests[i: i + batch])

    # warmup: one full pass, so every compile and every statistics-seeded
    # capacity growth (overflow -> doubled caps -> retrace) lands before
    # the clock starts — we measure the steady serving state
    serve_pass()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        serve_pass()
        best = min(best, time.perf_counter() - t0)
    return len(requests) / best


def run(scale: float = 1.0, csv: Optional[common.Csv] = None,
        backend: str = "jit", n_requests: int = 96,
        out_path: str = DEFAULT_OUT) -> Dict[str, float]:
    ds = common.facade(scale, threshold=0.25)
    requests = _requests(ds, n_requests)
    qps: Dict[str, float] = {}
    for batch in BATCH_SIZES:
        # fresh engine per shape: each measurement owns its caches
        eng = Engine(ds, backend=backend)
        qps[str(batch)] = _qps(eng, requests, batch)
        if csv is not None:
            csv.add(f"serve_qps_batch{batch}",
                    1.0 / qps[str(batch)],
                    f"{qps[str(batch)]:.0f} q/s")
    report = {
        "scale": scale,
        "backend": backend,
        "n_requests": n_requests,
        "qps": qps,
        "speedup_32_over_1": qps["32"] / qps["1"],
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    return report


if __name__ == "__main__":
    print(json.dumps(run(scale=0.5), indent=2))
