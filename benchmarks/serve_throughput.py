"""Serving throughput vs micro-batch size (the batched-execution payoff).

Serves one templated workload (the ST-1-style ``follows → email`` star,
constants cycling over users) through the jit backend at each micro-batch
size and reports queries/sec.  Batch size 1 is the per-request path
(``Engine.query``); larger sizes stack the constants into one XLA launch
(``Engine.query_batch``), so the speedup measures pure launch/dispatch
amortization — compile time is excluded by a warmup pass per batch shape.

One engine serves every batch size, so its :class:`~repro.runtime
.BatchTuner` sees all the shapes: a bucket that measures slower per slot
than a smaller bucket is retired mid-benchmark and larger submissions
chunk down to the surviving shape.  A **bucket inversion** — a larger
batch size serving fewer q/s than a smaller one beyond tolerance — is a
hard failure (``strict=True``): the exact regression this file once
recorded silently (batch-32 < batch-8) must now either be cured by the
tuner or fail the run.

Emits ``BENCH_serve_throughput.json``::

    {"scale": ..., "backend": "jit", "n_requests": ...,
     "qps": {"1": ..., "8": ..., "32": ...},
     "speedup_32_over_1": ...,
     "tuner": {"active": [...], "retired": {...}},
     "inversions": []}
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Sequence

from benchmarks import common
from repro.engine import Engine

BATCH_SIZES = (1, 8, 32)
DEFAULT_OUT = "BENCH_serve_throughput.json"
# a larger batch size must serve at least this fraction of every smaller
# size's throughput — below it, the bigger bucket is a measured regression
INVERSION_TOLERANCE = 0.9


def _requests(ds, n: int) -> List[str]:
    n_users = ds.schema.n_users if ds.schema is not None else 64
    return [
        f"SELECT * WHERE {{ wsdbm:User{u % n_users} wsdbm:follows ?v . "
        f"?v sorg:email ?e }}"
        for u in range(n)
    ]


def _qps(eng: Engine, requests: List[str], batch: int,
         repeats: int = 3) -> float:
    def serve_pass() -> None:
        if batch == 1:
            for q in requests:
                eng.query(q)
        else:
            for i in range(0, len(requests), batch):
                eng.query_batch(requests[i: i + batch])

    # warmup: one full pass, so every compile and every statistics-seeded
    # capacity growth (overflow -> doubled caps -> retrace) lands before
    # the clock starts — we measure the steady serving state
    serve_pass()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        serve_pass()
        best = min(best, time.perf_counter() - t0)
    return len(requests) / best


def run(scale: float = 1.0, csv: Optional[common.Csv] = None,
        backend: str = "jit", n_requests: int = 96,
        out_path: str = DEFAULT_OUT,
        batch_sizes: Sequence[int] = BATCH_SIZES,
        batch_shapes: Optional[Sequence[int]] = None,
        strict: bool = True) -> Dict[str, object]:
    ds = common.facade(scale, threshold=0.25)
    requests = _requests(ds, n_requests)
    sizes = sorted(set(int(b) for b in batch_sizes))
    # ONE engine across sizes, measured smallest-first: the tuner
    # accumulates per-shape evidence as sizes grow, so a larger bucket
    # that measures slower per slot gets retired while the benchmark is
    # still running — submissions at that size chunk down to the
    # surviving shape instead of recording the regression as fate
    eng = Engine(ds, backend=backend, batch_shapes=batch_shapes)
    qps: Dict[str, float] = {}
    for batch in sizes:
        qps[str(batch)] = _qps(eng, requests, batch)
        if csv is not None:
            csv.add(f"serve_qps_batch{batch}",
                    1.0 / qps[str(batch)],
                    f"{qps[str(batch)]:.0f} q/s")
    inversions: List[str] = []
    for i, big in enumerate(sizes):
        for small in sizes[:i]:
            if qps[str(big)] < INVERSION_TOLERANCE * qps[str(small)]:
                inversions.append(
                    f"batch-{big} serves {qps[str(big)]:.0f} q/s < "
                    f"{INVERSION_TOLERANCE:.0%} of batch-{small} "
                    f"({qps[str(small)]:.0f} q/s)")
    tuner = eng.tuner.report()
    report = {
        "scale": scale,
        "backend": backend,
        "n_requests": n_requests,
        "qps": qps,
        f"speedup_{sizes[-1]}_over_{sizes[0]}":
            qps[str(sizes[-1])] / qps[str(sizes[0])],
        "tuner": {"active": tuner["active"], "retired": tuner["retired"]},
        "inversions": inversions,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    if strict and inversions:
        raise RuntimeError("micro-batch bucket inversion:\n  "
                           + "\n  ".join(inversions))
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--backend", default="jit")
    ap.add_argument("--n-requests", type=int, default=96)
    ap.add_argument("--batch-sizes", default=None,
                    help="comma-separated submission sizes (default 1,8,32)")
    ap.add_argument("--batch-shapes", default=None,
                    help="comma-separated static bucket menu for the engine")
    ap.add_argument("--no-strict", action="store_true",
                    help="record inversions without failing")
    args = ap.parse_args()
    parse = lambda s: tuple(int(t) for t in s.replace(",", " ").split())
    print(json.dumps(run(
        scale=args.scale, out_path=args.out, backend=args.backend,
        n_requests=args.n_requests,
        batch_sizes=parse(args.batch_sizes) if args.batch_sizes
        else BATCH_SIZES,
        batch_shapes=parse(args.batch_shapes) if args.batch_shapes
        else None,
        strict=not args.no_strict), indent=2))
