"""§Perf hillclimbing driver: named variants of a dry-run cell, each a
hypothesis about the dominant roofline term, re-lowered + re-analysed and
appended to results/perf.jsonl.

    PYTHONPATH=src python -m benchmarks.perf_experiments \
        --cell deepseek-moe-16b:train_4k --variant base,cap1.0,zero1 \
        --out results/perf.jsonl

Run inside a dry-run process (the module sets XLA_FLAGS itself on import
via repro.launch.dryrun).
"""

from __future__ import annotations

# dryrun import MUST precede other jax usage: it forces 512 host devices
from repro.launch import dryrun  # noqa: F401  (sets XLA_FLAGS)

import argparse
import dataclasses
import json
import os
import time
from typing import Callable, Dict

import jax

from repro.configs import get
from repro.launch.dryrun import _raw_costs, analyze, build_cell, \
    build_s2rdf_cell, corrected_costs, pick_unroll
from repro.launch.mesh import make_production_mesh
from repro.models.api import model_flops
from repro.models.config import SHAPES, MoEConfig


# --- variant definitions: cfg transformers --------------------------------

def _moe_cap(cfg, factor):
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=factor))


def _moe_blocked(cfg, nb=16):
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, dispatch_blocks=nb))


VARIANTS: Dict[str, Callable] = {
    "base": lambda cfg: cfg,
    "noremat": lambda cfg: dataclasses.replace(cfg, remat=False),
    "remat_all": lambda cfg: dataclasses.replace(cfg, remat=True),
    "zero1": lambda cfg: dataclasses.replace(cfg, zero1=True),
    "unroll2": lambda cfg: dataclasses.replace(cfg, scan_unroll=2),
    "bf16params": lambda cfg: dataclasses.replace(cfg, param_dtype="bfloat16"),
    "cap1.0": lambda cfg: _moe_cap(cfg, 1.0),
    "cap2.0": lambda cfg: _moe_cap(cfg, 2.0),
    "blocked": lambda cfg: _moe_blocked(cfg, 16),
    "blocked_noremat": lambda cfg: dataclasses.replace(
        _moe_blocked(cfg, 16), remat=False),
    "blocked_cap1_noremat": lambda cfg: dataclasses.replace(
        _moe_cap(_moe_blocked(cfg, 16), 1.0), remat=False),
    "dp_decode": lambda cfg: dataclasses.replace(cfg, dp_only_decode=True),
    "flash512": lambda cfg: dataclasses.replace(cfg, flash_chunk=512),
    "flash1024": lambda cfg: dataclasses.replace(cfg, flash_chunk=1024),
    "flash512_blocked_noremat": lambda cfg: dataclasses.replace(
        _moe_blocked(cfg, 16), flash_chunk=512, remat=False),
    "best_moe": lambda cfg: dataclasses.replace(
        _moe_cap(_moe_blocked(cfg, 16), 1.0), flash_chunk=512, remat=False),
    "best_moe_compress": lambda cfg: dataclasses.replace(
        _moe_cap(_moe_blocked(cfg, 16), 1.0), flash_chunk=512, remat=False),
    "dp_bf16": lambda cfg: dataclasses.replace(
        cfg, dp_only_decode=True, param_dtype="bfloat16"),
    "blocked8_cap1_noremat": lambda cfg: dataclasses.replace(
        _moe_cap(_moe_blocked(cfg, 8), 1.0), remat=False),
    "blocked_cap1_noremat": lambda cfg: dataclasses.replace(
        _moe_cap(_moe_blocked(cfg, 16), 1.0), remat=False),
    "flash512_only": lambda cfg: dataclasses.replace(cfg, flash_chunk=512),
    "chunk32": lambda cfg: dataclasses.replace(cfg, ssm_chunk=32),
    "chunk64": lambda cfg: dataclasses.replace(cfg, ssm_chunk=64),
    "chunk128": lambda cfg: dataclasses.replace(cfg, ssm_chunk=128),
    "chunk512": lambda cfg: dataclasses.replace(cfg, ssm_chunk=512),
}


def run_variant(arch: str, shape: str, variant: str) -> Dict:
    rec = {"arch": arch, "shape": shape, "variant": variant}
    t0 = time.time()
    cfg = VARIANTS[variant](get(arch))
    cell = next(c for c in SHAPES if c.name == shape)
    mesh = make_production_mesh()
    compress = variant.endswith("_compress")
    fn, structs = build_cell(cfg, cell, mesh, compress_grads=compress)
    compiled = fn.lower(*structs).compile()
    a1 = _raw_costs(compiled)
    g, k = cfg.n_groups, pick_unroll(cfg.n_groups)
    costs = None
    if k > 1 and cfg.scan_unroll == 1:
        cfg_k = dataclasses.replace(cfg, scan_unroll=k)
        fn_k, structs_k = build_cell(cfg_k, cell, mesh)
        ak = _raw_costs(fn_k.lower(*structs_k).compile())
        costs = corrected_costs(a1, ak, g, k)
    rec.update(analyze(compiled, 256, model_flops(cfg, cell), costs))
    rec["status"] = "ok"
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def run_s2rdf_variant(variant: str) -> Dict:
    """s2rdf variants: base (ExtVP) | vp (paper baseline layout) |
    dual (ExtVP + o-partitioned copies) | vp_dual."""
    rec = {"arch": "s2rdf", "shape": "-", "variant": variant}
    t0 = time.time()
    layout = "vp" if variant.startswith("vp") else "extvp"
    dual = variant.endswith("dual")
    ex, plan = build_s2rdf_cell("single", layout=layout, dual_partition=dual)
    compiled = ex.lower().compile()
    rec.update(analyze(compiled, 256, None))
    rec["plan"] = plan.describe()
    rec["status"] = "ok"
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape or s2rdf")
    ap.add_argument("--variant", required=True, help="comma list")
    ap.add_argument("--out", default="results/perf.jsonl")
    args = ap.parse_args()

    for variant in args.variant.split(","):
        if args.cell == "s2rdf":
            rec = run_s2rdf_variant(variant)
        else:
            arch, shape = args.cell.split(":")
            rec = run_variant(arch, shape, variant)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        brief = {k: rec.get(k) for k in
                 ("arch", "shape", "variant", "dominant", "compute_s",
                  "memory_s", "collective_s", "roofline_fraction", "wall_s")}
        print(json.dumps(brief))


if __name__ == "__main__":
    main()
