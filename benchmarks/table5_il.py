"""Paper Table 5 / Fig. 15: Incremental Linear Testing — linear chains of
diameter 5..10, user-bound / retailer-bound / unbound, ExtVP vs VP."""

from __future__ import annotations

from benchmarks.common import Csv, catalog, dataset, time_query
from repro.rdf.workloads import il_queries


def run(scale: float = 1.0, csv: Csv | None = None) -> Csv:
    csv = csv or Csv()
    tt, d, sch = dataset(scale)
    cat = catalog(scale)
    il3_max = 6 if scale <= 2 else 5   # unbound chains grow ~linearly in |G|
    queries = il_queries(sch, seed=42, n_instances=3, il3_max_diameter=il3_max)

    for name, instances in sorted(queries.items()):
        for layout in ("extvp", "vp"):
            times, rows = [], 0
            for qtext in instances:
                t, r = time_query(qtext, cat, layout, repeats=2)
                times.append(t)
                rows = max(rows, r)
            am = sum(times) / len(times)
            csv.add(f"table5/{name}/{layout}", am, f"rows={rows}")
    for diameter in range(il3_max + 1, 11):   # paper Table 5 'F' convention
        csv.add(f"table5/IL-3-{diameter}/extvp", 0.0, "F(result-set-explosion)")
    return csv


if __name__ == "__main__":
    run().emit()
