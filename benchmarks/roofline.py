"""Roofline table generator: reads results/dryrun.jsonl (the compiled
dry-run artifacts) and emits the §Roofline markdown table + per-cell
bottleneck notes.  Dedup keeps the LAST record per (arch, shape, mesh)
so re-runs of individual cells supersede older entries.

    PYTHONPATH=src python -m benchmarks.roofline [--jsonl results/dryrun.jsonl]
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List

_MOVE_NOTES = {
    ("memory_s", "train"): "cut HBM traffic: fewer remat passes / fused "
                           "group body / bf16 master weights",
    ("memory_s", "prefill"): "fuse attention (flash-style tiling) to stop "
                             "materializing S×S scores",
    ("memory_s", "decode"): "decode is KV-cache-bandwidth-bound by nature; "
                            "shrink KV (GQA already), quantize cache, or "
                            "batch more requests per read",
    ("collective_s", "train"): "overlap grad all-reduce with backward scan; "
                               "compress grads (bf16 + error feedback)",
    ("collective_s", "prefill"): "resharding between TP blocks — keep "
                                 "activations model-sharded across layers",
    ("collective_s", "decode"): "all-gather of TP partials each token; "
                                "widen batch or use comm-avoiding head layout",
    ("compute_s", "train"): "near roofline — raise MXU utilization via "
                            "larger per-chip matmul tiles",
    ("compute_s", "prefill"): "near roofline — already compute-bound",
    ("compute_s", "decode"): "compute-bound decode: batch is large enough",
}


def load(path: str) -> List[dict]:
    recs: Dict[tuple, dict] = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r  # keep last
    return list(recs.values())


def emit_table(recs: List[dict], mesh: str = "single") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | compute s | memory s (hi/lo) | collective s | "
           "dominant | MODEL/HLO flops | roofline frac (lo..hi) | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | "
                       f"{r['reason'][:60]}… |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | {r.get('error','')[:60]} |")
            continue
        kind = "train" if r["shape"].startswith("train") else (
            "prefill" if r["shape"].startswith("prefill") else "decode")
        note = _MOVE_NOTES.get((r["dominant"], kind), "")
        uc = r.get("useful_compute_ratio")
        rf, rfu = r.get("roofline_fraction"), r.get("roofline_fraction_upper")
        uc_s = f"{uc:.2f}" if uc else "n/a"
        rf_s = f"{rf*100:.1f}%..{rfu*100:.1f}%" if rf else "n/a"
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} / {r.get('memory_s_lower', 0):.3g} "
            f"| {r['collective_s']:.3g} "
            f"| {r['dominant'].replace('_s','')} "
            f"| {uc_s} | {rf_s} | {note} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.jsonl)
    print(emit_table(recs, args.mesh))


if __name__ == "__main__":
    main()
