"""Modifier-bearing queries on the device path: jit vs eager.

Before the modifier pipeline, every FILTER / DISTINCT / ORDER BY /
LIMIT query silently fell back to the eager host engine on the device
backends; now the whole spine compiles into the static-shape XLA
program (scan → join → filter-mask → project → sort-dedup → lexsort →
static slice), and this benchmark measures the payoff on WatDiv-style
templates — per-request (``Engine.query``) and micro-batched
(``Engine.query_batch``).

Emits ``BENCH_modifier_queries.json``::

    {"scale": ..., "n_requests": ..., "batch": ...,
     "queries": {name: {"eager_qps": ..., "jit_qps": ...,
                        "jit_batch_qps": ..., "speedup": ...,
                        "device_fallbacks": 0}, ...}}

``device_fallbacks`` is asserted 0 for every template: the benchmark
doubles as a regression gate that the modifier spine stays on device.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

from benchmarks import common
from repro.engine import Engine

DEFAULT_OUT = "BENCH_modifier_queries.json"
BATCH = 16


def _templates(ds) -> Dict[str, List[str]]:
    """WatDiv-style modifier workloads; constants cycle over users so
    the jit path exercises constant re-binding, not just re-execution."""
    n_users = ds.schema.n_users if ds.schema is not None else 64

    def users(fmt: str, n: int) -> List[str]:
        return [fmt.format(u=u % n_users) for u in range(n)]

    return {
        "follows_distinct_order_limit": users(
            "SELECT DISTINCT ?v WHERE {{ wsdbm:User{u} wsdbm:follows ?v . "
            "?v sorg:email ?e }} ORDER BY ?v LIMIT 10", 64),
        "likes_filter_price": users(
            "SELECT ?p ?x WHERE {{ wsdbm:User{u} wsdbm:likes ?p . "
            "?p sorg:price ?x FILTER(?x < 300) }} ORDER BY DESC(?x) LIMIT 5",
            64),
        "rating_filter_order": [
            "SELECT DISTINCT ?p WHERE { ?p rev:hasReview ?r . "
            "?r rev:rating ?x FILTER(?x > 5) } ORDER BY ?p LIMIT 20"] * 32,
    }


def _qps(eng: Engine, requests: List[str], batch: int,
         repeats: int = 3) -> float:
    def serve_pass() -> None:
        if batch == 1:
            for q in requests:
                eng.query(q)
        else:
            for i in range(0, len(requests), batch):
                eng.query_batch(requests[i: i + batch])

    serve_pass()                       # warmup: compiles + cap growth
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        serve_pass()
        best = min(best, time.perf_counter() - t0)
    return len(requests) / best


def run(scale: float = 1.0, csv: Optional[common.Csv] = None,
        out_path: str = DEFAULT_OUT) -> Dict[str, object]:
    ds = common.facade(scale, threshold=0.25)
    queries: Dict[str, Dict[str, float]] = {}
    for name, requests in _templates(ds).items():
        eager = Engine(ds, backend="eager")
        jit1 = Engine(ds, backend="jit")
        jitb = Engine(ds, backend="jit")
        eager_qps = _qps(eager, requests, batch=1)
        jit_qps = _qps(jit1, requests, batch=1)
        jit_batch_qps = _qps(jitb, requests, batch=BATCH)
        fallbacks = jit1.metrics.device_fallbacks + \
            jitb.metrics.device_fallbacks
        assert fallbacks == 0, \
            f"{name}: modifier template fell back to eager"
        queries[name] = {
            "eager_qps": eager_qps,
            "jit_qps": jit_qps,
            "jit_batch_qps": jit_batch_qps,
            "speedup": jit_batch_qps / eager_qps,
            "device_fallbacks": fallbacks,
        }
        if csv is not None:
            csv.add(f"modifiers/{name}", 1e6 / jit_batch_qps,
                    f"jit_b{BATCH} {jit_batch_qps:.0f}q/s "
                    f"x{jit_batch_qps / eager_qps:.1f} vs eager")
    report = {
        "scale": scale,
        "n_requests": {k: len(v) for k, v in _templates(ds).items()},
        "batch": BATCH,
        "queries": queries,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    print(json.dumps(run(scale=args.scale, out_path=args.out), indent=2))
