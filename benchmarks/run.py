"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--scale`` sets the WatDiv
scale factor (default 5.0 ≈ 1.5·10^5 triples — big enough that the
paper's selectivity separation is visible on one CPU host; the paper's
SF10000 ≈ 1.09·10^9 runs the same code on a cluster).  The roofline/perf
numbers live in results/dryrun.jsonl (see launch/dryrun.py), not here —
this harness measures the *running* engine.
"""

from __future__ import annotations

import argparse

from benchmarks import adaptive_routing, common, modifier_queries, \
    plan_enum, sec74_threshold, serve_throughput, store_load, table2_load, \
    table3_st, table4_basic, table5_il, trace_overhead, verify_overhead
from benchmarks.common import Csv

TABLES = {
    "table2": table2_load.run,
    "table3": table3_st.run,
    "table4": table4_basic.run,
    "table5": table5_il.run,
    "sec74": sec74_threshold.run,
    "serve": serve_throughput.run,   # writes BENCH_serve_throughput.json
    "modifiers": modifier_queries.run,  # writes BENCH_modifier_queries.json
    "store": store_load.run,         # writes BENCH_store_load.json
    "routing": adaptive_routing.run,  # writes BENCH_adaptive_routing.json
    "plan_enum": plan_enum.run,      # writes BENCH_plan_enum.json
    "verify": verify_overhead.run,   # writes BENCH_verify_overhead.json
    "trace": trace_overhead.run,     # writes BENCH_trace_overhead.json
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=5.0)
    ap.add_argument("--only", default=None, choices=list(TABLES))
    ap.add_argument("--backend", default="eager",
                    help="ExecutionBackend registry key for query timing")
    args = ap.parse_args()

    common.set_default_backend(args.backend)
    csv = Csv()
    print("name,us_per_call,derived")
    for name, fn in TABLES.items():
        if args.only and name != args.only:
            continue
        fn(scale=args.scale, csv=csv)
    csv.emit()


if __name__ == "__main__":
    main()
