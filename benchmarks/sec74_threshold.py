"""Paper §7.4: the SF-threshold τ trade-off — store size vs retained
performance benefit, swept over τ ∈ {0.1, 0.25, 0.5, 1.0}.

The paper's claim: τ=0.25 cuts ExtVP from ~11n to ~2n tuples while
keeping ~95% of the speedup."""

from __future__ import annotations

from benchmarks.common import Csv, catalog, time_query
from repro.rdf.workloads import ST_QUERIES

TAUS = (0.1, 0.25, 0.5, 1.0)


def run(scale: float = 1.0, csv: Csv | None = None) -> Csv:
    csv = csv or Csv()
    # benefit metric: total ST-suite time per τ, relative to VP
    cat_full = catalog(scale, threshold=1.0)
    t_vp = sum(time_query(q, cat_full, "vp")[0] for q in ST_QUERIES.values())

    base_gain = None
    for tau in TAUS:
        cat_t = catalog(scale, threshold=tau)
        rep = cat_t.storage_report()
        t_ext = sum(time_query(q, cat_t, "extvp")[0]
                    for q in ST_QUERIES.values())
        gain = t_vp - t_ext
        if tau == 1.0:
            base_gain = gain
        csv.add(f"sec74/tau{tau}", t_ext,
                f"tuples_xVP={rep['extvp_over_vp']:.2f}"
                f";tables={int(rep['extvp_tables'])}"
                f";vp_total={t_vp*1e6:.0f}us")
    # retained-benefit summary (needs tau sweep above; base_gain set at 1.0)
    for tau in TAUS[:-1]:
        cat_t = catalog(scale, threshold=tau)
        t_ext = sum(time_query(q, cat_t, "extvp")[0]
                    for q in ST_QUERIES.values())
        retained = (t_vp - t_ext) / max(base_gain, 1e-9)
        csv.add(f"sec74/retained_tau{tau}", 0.0, f"{retained*100:.0f}%")
    return csv


if __name__ == "__main__":
    run().emit()
