"""Paper Table 3 / Fig. 13: Selectivity Testing — ExtVP vs VP runtimes
across the OS/SO/SS selectivity classes, plus the ST-8 statistics-only
empties."""

from __future__ import annotations

from benchmarks.common import Csv, catalog, time_query
from repro.rdf.workloads import ST_QUERIES


def run(scale: float = 1.0, csv: Csv | None = None) -> Csv:
    csv = csv or Csv()
    cat = catalog(scale)
    for name, qtext in ST_QUERIES.items():
        t_ext, rows = time_query(qtext, cat, "extvp")
        t_vp, rows_vp = time_query(qtext, cat, "vp")
        assert rows == rows_vp, (name, rows, rows_vp)
        speedup = t_vp / max(t_ext, 1e-9)
        csv.add(f"table3/{name}/extvp", t_ext, f"rows={rows}")
        csv.add(f"table3/{name}/vp", t_vp, f"speedup={speedup:.2f}x")
    return csv


if __name__ == "__main__":
    run().emit()
