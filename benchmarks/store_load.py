"""Cold-start benchmark: booting from the persistent columnar store vs
re-running the full build pipeline (the lifecycle S2RDF's persist-once /
query-many design buys, paper §4–§5).

Three cold-start paths over the same WatDiv graph, best-of-N seconds:

* ``rebuild``     — ``build_catalog`` from the raw triples table (VP +
                    the full semi-join grid), i.e. the pre-store boot;
* ``load (lazy)`` — ``Dataset.load``: manifest + dictionary parse only,
                    column files memory-mapped on first touch;
* ``load (eager)``— ``Dataset.load(eager=True)``: every column file read
                    into RAM up front.

Also times the first query after a lazy boot (the "fault-in" cost the
laziness defers).  Emits ``BENCH_store_load.json`` and **asserts the
lazy load is ≥5x faster than the rebuild** at the bench scale — the
store's reason to exist.

    PYTHONPATH=src:. python benchmarks/store_load.py --scale 5.0
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
from typing import Dict

from benchmarks import common
from benchmarks.common import Csv

DEFAULT_OUT = "BENCH_store_load.json"
THRESHOLD = 0.25
MIN_SPEEDUP = 5.0
_QUERY = "SELECT * WHERE { ?u wsdbm:follows ?v . ?v sorg:email ?e }"


def _best(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(scale: float = 5.0, csv: Csv = None, repeats: int = 3,
        out: str = DEFAULT_OUT) -> Dict:
    from repro.core.stats import build_catalog
    from repro.engine import Dataset

    csv = csv or Csv()
    tt, d, sch = common.dataset(scale)
    ds = Dataset(catalog=build_catalog(tt, d, threshold=THRESHOLD),
                 dictionary=d, schema=sch)

    with tempfile.TemporaryDirectory() as tmp:
        store = os.path.join(tmp, "watdiv.store")
        ds.save(store)
        store_bytes = ds.storage_report()["store_bytes"]

        rebuild_s = _best(
            lambda: build_catalog(tt, d, threshold=THRESHOLD), repeats)
        lazy_s = _best(lambda: Dataset.load(store), repeats)
        eager_s = _best(lambda: Dataset.load(store, eager=True), repeats)

        # fault-in: first query on a freshly lazy-loaded dataset
        cold = Dataset.load(store)
        t0 = time.perf_counter()
        n_rows = len(cold.engine("eager").query(_QUERY))
        first_query_s = time.perf_counter() - t0

    speedup_lazy = rebuild_s / max(lazy_s, 1e-9)
    speedup_eager = rebuild_s / max(eager_s, 1e-9)
    result = {
        "scale": scale, "threshold": THRESHOLD,
        "n_triples": int(ds.n_triples),
        "store_bytes": int(store_bytes),
        "rebuild_seconds": rebuild_s,
        "load_lazy_seconds": lazy_s,
        "load_eager_seconds": eager_s,
        "first_query_seconds": first_query_s,
        "first_query_rows": int(n_rows),
        "speedup_lazy": speedup_lazy,
        "speedup_eager": speedup_eager,
        "min_speedup": MIN_SPEEDUP,
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=2)

    csv.add("store_rebuild", rebuild_s, f"{ds.n_triples}_triples")
    csv.add("store_load_lazy", lazy_s, f"{speedup_lazy:.1f}x")
    csv.add("store_load_eager", eager_s, f"{speedup_eager:.1f}x")
    csv.add("store_first_query", first_query_s, f"{n_rows}_rows")

    assert speedup_lazy >= MIN_SPEEDUP, (
        f"lazy store cold-start is only {speedup_lazy:.1f}x faster than a "
        f"build_catalog rebuild (need >= {MIN_SPEEDUP}x at scale {scale})")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=5.0)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    csv = Csv()
    result = run(scale=args.scale, csv=csv, repeats=args.repeats,
                 out=args.out)
    print("name,us_per_call,derived")
    csv.emit()
    print(f"lazy cold-start speedup over rebuild: "
          f"{result['speedup_lazy']:.1f}x -> {args.out}")


if __name__ == "__main__":
    main()
