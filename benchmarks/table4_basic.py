"""Paper Table 4 / Fig. 14: Basic Testing (star/linear/snowflake/complex),
ExtVP vs VP vs TT vs PT (Sempala-style) layouts, AM runtime over template
instantiations and per-category aggregates.

Doubles as the **device-coverage gate**: the full basic suite is re-run
on the jit and distributed backends and every query must execute on the
device — ``device_fallbacks`` is asserted 0 per backend, so a coverage
regression (an operator silently bailing back to the eager host path)
fails the benchmark and with it the ``tests-pallas`` CI job.

Emits ``BENCH_table4_basic.json``::

    {"scale": ..., "n_queries": ...,
     "device_gate": {backend: {"templates": {name: am_seconds},
                               "device_fallbacks": 0}, ...}}
"""

from __future__ import annotations

import argparse
import json
import time
from collections import defaultdict
from typing import Dict, Optional

from benchmarks.common import Csv, catalog, dataset, facade, time_query
from repro.rdf.workloads import basic_queries

DEFAULT_OUT = "BENCH_table4_basic.json"


def device_gate(scale: float = 1.0, csv: Optional[Csv] = None,
                out_path: str = DEFAULT_OUT) -> Dict[str, object]:
    """Run the FULL basic suite on every device backend and assert that
    no query fell back to the eager host engine (the fallback classes —
    OPTIONAL, UNION, unbound predicates, all modifier spines — compile
    now; nonzero here is a regression)."""
    import jax

    from repro.engine import Engine

    ds = facade(scale)
    queries = basic_queries(ds.schema, seed=42, n_instances=3)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    engines = {
        "jit": Engine(ds, backend="jit"),
        "distributed": Engine(ds, backend="distributed", mesh=mesh),
    }
    n_queries = sum(len(v) for v in queries.values())
    gate: Dict[str, object] = {}
    for bname, eng in engines.items():
        templates: Dict[str, float] = {}
        for name, instances in queries.items():
            times = []
            for qtext in instances:
                best = float("inf")
                for _ in range(2):
                    t0 = time.perf_counter()
                    eng.query(qtext)
                    best = min(best, time.perf_counter() - t0)
                times.append(best)
            templates[name] = sum(times) / len(times)
        fallbacks = eng.metrics.device_fallbacks
        assert fallbacks == 0, (
            f"{bname}: {fallbacks} of {n_queries} basic-suite queries "
            f"fell back to the eager host path — device coverage "
            f"regression")
        gate[bname] = {"templates": templates, "device_fallbacks": fallbacks}
        if csv is not None:
            am = sum(templates.values()) / len(templates)
            csv.add(f"table4/device-gate/{bname}", am,
                    f"n={n_queries} fallbacks=0")
    report = {"scale": scale, "n_queries": n_queries, "device_gate": gate}
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    return report


def run(scale: float = 1.0, csv: Csv | None = None,
        out_path: str = DEFAULT_OUT) -> Csv:
    csv = csv or Csv()
    tt, d, sch = dataset(scale)
    cat = catalog(scale)
    queries = basic_queries(sch, seed=42, n_instances=3)

    cats = defaultdict(lambda: defaultdict(list))
    for name, instances in queries.items():
        per_layout = {}
        for layout in ("extvp", "vp", "tt", "pt"):
            times, rows = [], 0
            for qtext in instances:
                t, r = time_query(qtext, cat, layout, repeats=2)
                times.append(t)
                rows += r
            am = sum(times) / len(times)
            per_layout[layout] = (am, rows)
            cats[name[0]][layout].append(am)
        ext, vp, ttime, pt = (per_layout[k][0]
                              for k in ("extvp", "vp", "tt", "pt"))
        csv.add(f"table4/{name}/extvp", ext, f"rows={per_layout['extvp'][1]}")
        csv.add(f"table4/{name}/vp", vp, f"speedup={vp/max(ext,1e-9):.2f}x")
        csv.add(f"table4/{name}/tt", ttime, f"speedup={ttime/max(ext,1e-9):.2f}x")
        csv.add(f"table4/{name}/pt", pt, f"speedup={pt/max(ext,1e-9):.2f}x")

    for shape, layouts in sorted(cats.items()):
        for layout, times in layouts.items():
            am = sum(times) / len(times)
            csv.add(f"table4/AM-{shape}/{layout}", am, f"n={len(times)}")

    device_gate(scale, csv=csv, out_path=out_path)
    return csv


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(scale=args.scale, out_path=args.out).emit()
