"""Paper Table 4 / Fig. 14: Basic Testing (star/linear/snowflake/complex),
ExtVP vs VP vs TT vs PT (Sempala-style) layouts, AM runtime over template
instantiations and per-category aggregates."""

from __future__ import annotations

from collections import defaultdict

from benchmarks.common import Csv, catalog, dataset, time_query
from repro.rdf.workloads import basic_queries


def run(scale: float = 1.0, csv: Csv | None = None) -> Csv:
    csv = csv or Csv()
    tt, d, sch = dataset(scale)
    cat = catalog(scale)
    queries = basic_queries(sch, seed=42, n_instances=3)

    cats = defaultdict(lambda: defaultdict(list))
    for name, instances in queries.items():
        per_layout = {}
        for layout in ("extvp", "vp", "tt", "pt"):
            times, rows = [], 0
            for qtext in instances:
                t, r = time_query(qtext, cat, layout, repeats=2)
                times.append(t)
                rows += r
            am = sum(times) / len(times)
            per_layout[layout] = (am, rows)
            cats[name[0]][layout].append(am)
        ext, vp, ttime, pt = (per_layout[k][0]
                              for k in ("extvp", "vp", "tt", "pt"))
        csv.add(f"table4/{name}/extvp", ext, f"rows={per_layout['extvp'][1]}")
        csv.add(f"table4/{name}/vp", vp, f"speedup={vp/max(ext,1e-9):.2f}x")
        csv.add(f"table4/{name}/tt", ttime, f"speedup={ttime/max(ext,1e-9):.2f}x")
        csv.add(f"table4/{name}/pt", pt, f"speedup={pt/max(ext,1e-9):.2f}x")

    for shape, layouts in sorted(cats.items()):
        for layout, times in layouts.items():
            am = sum(times) / len(times)
            csv.add(f"table4/AM-{shape}/{layout}", am, f"n={len(times)}")
    return csv


if __name__ == "__main__":
    run().emit()
