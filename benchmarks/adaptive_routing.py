"""Adaptive backend routing vs static backends (the ``backend="auto"``
payoff).

``BENCH_modifier_queries.json`` proves no static backend choice is right:
jit is ~0.5x eager on one WatDiv template and ~4x on another.  This
benchmark serves each template micro-batched (the serving-layer shape,
where the winners actually differ) through every static backend and
through the adaptive runtime, and checks that ``auto`` lands within 5% of
the best static backend and strictly above the worst — per template, with
the winner *measured* by the router, never table-driven.

Emits ``BENCH_adaptive_routing.json``::

    {"scale": ..., "batch": 16, "backends": ["eager", "jit"],
     "templates": {name: {"eager_qps": ..., "jit_qps": ...,
                          "auto_qps": ..., "best_static": "jit",
                          "auto_vs_best": 0.99, "auto_vs_worst": 3.1,
                          "router_choice": "jit",
                          "router_reason": "measured"}, ...},
     "criteria": {"min_vs_best": 0.95, "pass": true}}

With ``strict=True`` (the default) the criteria are enforced: the report
is still written, then a ``RuntimeError`` lists every violation — the
benchmark doubles as the regression gate for the routing layer.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

from benchmarks import common
from repro.engine import Engine, RuntimeConfig, template_signature

DEFAULT_OUT = "BENCH_adaptive_routing.json"
BATCH = 16
STATIC_BACKENDS = ("eager", "jit")
MIN_VS_BEST = 0.95


MIN_PASS_REQUESTS = 256


def _templates(ds) -> Dict[str, List[str]]:
    """The WatDiv serving suite: the plain star from serve_throughput
    plus the modifier templates — per-template winners differ across
    them, which is the whole case for routing.  Request lists are tiled
    up to ``MIN_PASS_REQUESTS`` so one timed pass is tens of
    milliseconds: passes comparable to an OS scheduler quantum measure
    the scheduler, not the engine."""
    from benchmarks import modifier_queries, serve_throughput
    out = {"follows_email_star": serve_throughput._requests(ds, 64)}
    out.update(modifier_queries._templates(ds))
    for name, reqs in out.items():
        reps = -(-MIN_PASS_REQUESTS // len(reqs))
        out[name] = reqs * reps
    return out


def _serve_pass(eng: Engine, requests: List[str]) -> None:
    for i in range(0, len(requests), BATCH):
        eng.query_batch(requests[i: i + BATCH])


def _warm(eng: Engine, requests: List[str], converge: bool = False) -> None:
    """One pass lands compiles and capacity-growth retraces before the
    clock starts; the auto engine additionally warms until the router
    reports a measured choice (its warmup rotation deliberately visits
    the slow backend — measuring through it would punish adaptivity for
    doing its job)."""
    _serve_pass(eng, requests)
    if converge:
        sig = template_signature(requests[0])
        for _ in range(8):
            st = eng.router.report()["signatures"].get(sig, {})
            if st.get("reason") == "measured":
                break
            _serve_pass(eng, requests)


def _qps_interleaved(engines: Dict[str, Engine], requests: List[str],
                     repeats: int = 7) -> Dict[str, float]:
    """Best-of-N pass time per engine, with the engines measured
    round-robin inside each repeat — machine-wide drift between rounds
    (the container's noisy neighbors) hits every engine alike instead of
    whichever happened to be measured last."""
    best = {name: float("inf") for name in engines}
    for _ in range(repeats):
        for name, eng in engines.items():
            t0 = time.perf_counter()
            _serve_pass(eng, requests)
            best[name] = min(best[name], time.perf_counter() - t0)
    return {name: len(requests) / t for name, t in best.items()}


def _auto_engine(ds) -> Engine:
    # two discarded + two counted launches per backend: the discards
    # absorb XLA compiles AND the capacity-growth retraces that would
    # otherwise poison a single counted sample.  Probe sparsely: probing
    # cadence is an operator knob sized to the serving window, and this
    # window is a few hundred requests — a default-cadence probe pass
    # would dominate it (probe/drift behavior is covered by
    # tests/test_runtime.py, not measured here).
    return Engine(ds, backend="auto",
                  runtime=RuntimeConfig(router_warmup=2, router_discard=2,
                                        router_probe_every=2048))


def run(scale: float = 1.0, csv: Optional[common.Csv] = None,
        out_path: str = DEFAULT_OUT, strict: bool = True
        ) -> Dict[str, object]:
    ds = common.facade(scale, threshold=0.25)
    templates = _templates(ds)
    results: Dict[str, Dict[str, object]] = {}
    violations: List[str] = []
    for name, requests in templates.items():
        # fresh engine per measurement: each owns its caches
        engines = {b: Engine(ds, backend=b) for b in STATIC_BACKENDS}
        auto_eng = engines["auto"] = _auto_engine(ds)
        for b, eng in engines.items():
            _warm(eng, requests, converge=(b == "auto"))
        qps = _qps_interleaved(engines, requests)
        static = {b: qps[b] for b in STATIC_BACKENDS}
        auto_qps = qps["auto"]
        sig = template_signature(requests[0])
        route = auto_eng.router.report()["signatures"].get(sig, {})
        best_b = max(static, key=static.get)
        worst_b = min(static, key=static.get)
        entry = {
            **{f"{b}_qps": q for b, q in static.items()},
            "auto_qps": auto_qps,
            "best_static": best_b,
            "auto_vs_best": auto_qps / static[best_b],
            "auto_vs_worst": auto_qps / static[worst_b],
            "router_choice": route.get("choice"),
            "router_reason": route.get("reason"),
        }
        results[name] = entry
        if entry["auto_vs_best"] < MIN_VS_BEST:
            violations.append(
                f"{name}: auto {auto_qps:.0f} q/s is "
                f"{entry['auto_vs_best']:.2f}x best static "
                f"({best_b} {static[best_b]:.0f} q/s) < {MIN_VS_BEST}")
        # "faster than the worst" only means something when the statics
        # actually differ — when best ≈ worst (within the same 5% band)
        # the vs_best criterion already covers the template
        if len(static) > 1 and entry["auto_vs_worst"] <= 1.0 and \
                static[worst_b] < MIN_VS_BEST * static[best_b]:
            violations.append(
                f"{name}: auto {auto_qps:.0f} q/s not above worst static "
                f"({worst_b} {static[worst_b]:.0f} q/s)")
        if csv is not None:
            csv.add(f"routing/{name}", 1e6 / auto_qps,
                    f"auto {auto_qps:.0f}q/s -> {route.get('choice')} "
                    f"({entry['auto_vs_best']:.2f}x best)")
    report = {
        "scale": scale,
        "batch": BATCH,
        "backends": list(STATIC_BACKENDS),
        "n_requests": {k: len(v) for k, v in templates.items()},
        "templates": results,
        "criteria": {"min_vs_best": MIN_VS_BEST,
                     "pass": not violations,
                     "violations": violations},
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    if strict and violations:
        raise RuntimeError(
            "adaptive routing below static baselines:\n  "
            + "\n  ".join(violations))
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--no-strict", action="store_true",
                    help="record criteria violations without failing")
    args = ap.parse_args()
    print(json.dumps(run(scale=args.scale, out_path=args.out,
                         strict=not args.no_strict), indent=2))
